"""Direct Cauchy-matrix products (Trummer's problem, paper §3.2.1).

The Cauchy matrix of the paper (Eq. 18) is ``C[j, i] = 1 / (lambda_j - mu_i)``
with sources ``lambda`` (old eigenvalues) and targets ``mu`` (updated
eigenvalues). Updating singular vectors is ``U2 = U1 @ C`` — n Trummer
instances sharing one geometry.

Two evaluation paths:

* ``cauchy_matmul``     — raw coordinates; fine when sources and targets are
  well separated relative to eps.
* ``cauchy_matmul_stable`` — anchored representation of targets
  (mu_i = src[anchor_i] + tau_i) so denominators near poles are computed
  without cancellation. This is the path the SVD updater uses.

Both are O(R * N * M) and memory-chunked over targets so big problems do not
materialize an (N, M) matrix more than a chunk at a time.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "cauchy_matrix",
    "cauchy_matvec",
    "cauchy_matmul",
    "cauchy_matmul_stable",
    "cauchy_colnorms_stable",
]


def cauchy_matrix(src: jax.Array, tgt: jax.Array) -> jax.Array:
    """C[j, i] = 1 / (src_j - tgt_i)."""
    return 1.0 / (src[:, None] - tgt[None, :])


def cauchy_matvec(weights: jax.Array, src: jax.Array, tgt: jax.Array) -> jax.Array:
    """f(tgt_i) = sum_j weights_j / (src_j - tgt_i)."""
    return cauchy_matmul(weights[None, :], src, tgt)[0]


@partial(jax.jit, static_argnames=("chunk",))
def cauchy_matmul(w: jax.Array, src: jax.Array, tgt: jax.Array, *, chunk: int = 2048) -> jax.Array:
    """out[r, i] = sum_j w[r, j] / (src_j - tgt_i).   w: (R, N) -> (R, M)."""
    r_dim, n = w.shape
    m = tgt.shape[0]
    if m <= chunk:
        c = 1.0 / (src[:, None] - tgt[None, :])
        return w @ c

    pad = (-m) % chunk
    tgt_p = jnp.pad(tgt, (0, pad), constant_values=1.0)
    n_chunks = (m + pad) // chunk
    tgt_c = tgt_p.reshape(n_chunks, chunk)

    def body(carry, tgt_blk):
        c = 1.0 / (src[:, None] - tgt_blk[None, :])
        return carry, w @ c

    _, out = lax.scan(body, 0, tgt_c)
    out = jnp.moveaxis(out, 0, 1).reshape(r_dim, n_chunks * chunk)
    return out[:, :m]


@partial(jax.jit, static_argnames=("chunk",))
def cauchy_matmul_stable(
    w: jax.Array,
    src: jax.Array,
    anchor: jax.Array,
    tau: jax.Array,
    *,
    src_valid: jax.Array | None = None,
    tgt_valid: jax.Array | None = None,
    chunk: int = 2048,
) -> jax.Array:
    """out[r, i] = sum_j w[r, j] / (src_j - mu_i),  mu_i = src[anchor_i] + tau_i.

    Denominator computed as (src_j - src[anchor_i]) - tau_i: exact pole
    differences plus a small offset — no cancellation when mu_i hugs a pole.
    Invalid sources/targets (deflation padding) are masked out / zeroed.
    """
    r_dim, n = w.shape
    m = anchor.shape[0]
    if src_valid is None:
        src_valid = jnp.ones((n,), bool)
    if tgt_valid is None:
        tgt_valid = jnp.ones((m,), bool)
    w = jnp.where(src_valid[None, :], w, 0.0)
    anchor_vals = src[anchor]

    def block(anchor_vals_b, tau_b, tgt_valid_b):
        delta = (src[:, None] - anchor_vals_b[None, :]) - tau_b[None, :]
        safe = jnp.where(delta == 0.0, 1.0, delta)
        c = jnp.where(src_valid[:, None] & tgt_valid_b[None, :] & (delta != 0.0), 1.0 / safe, 0.0)
        return w @ c

    if m <= chunk:
        return block(anchor_vals, tau, tgt_valid)

    pad = (-m) % chunk
    av = jnp.pad(anchor_vals, (0, pad))
    tv = jnp.pad(tau, (0, pad))
    vv = jnp.pad(tgt_valid, (0, pad), constant_values=False)
    n_chunks = (m + pad) // chunk

    def body(carry, xs):
        a_b, t_b, v_b = xs
        return carry, block(a_b, t_b, v_b)

    _, out = lax.scan(
        body, 0, (av.reshape(n_chunks, chunk), tv.reshape(n_chunks, chunk), vv.reshape(n_chunks, chunk))
    )
    out = jnp.moveaxis(out, 0, 1).reshape(r_dim, n_chunks * chunk)
    return out[:, :m]


def cauchy_colnorms_stable(
    zhat: jax.Array,
    src: jax.Array,
    anchor: jax.Array,
    tau: jax.Array,
    *,
    src_valid: jax.Array | None = None,
    tgt_valid: jax.Array | None = None,
) -> jax.Array:
    """Euclidean norms of the scaled Cauchy columns (paper Eq. 18 scaling).

    ||c_i||^2 = sum_j zhat_j^2 / (src_j - mu_i)^2, stable denominators.
    Invalid targets get norm 1 (their columns are identity passthroughs).
    """
    n = src.shape[0]
    m = anchor.shape[0]
    if src_valid is None:
        src_valid = jnp.ones((n,), bool)
    if tgt_valid is None:
        tgt_valid = jnp.ones((m,), bool)
    anchor_vals = src[anchor]
    delta = (src[:, None] - anchor_vals[None, :]) - tau[None, :]
    safe = jnp.where(delta == 0.0, 1.0, delta)
    inv2 = jnp.where(src_valid[:, None] & (delta != 0.0), 1.0 / (safe * safe), 0.0)
    nrm2 = jnp.sum((zhat * zhat)[:, None] * inv2, axis=0)
    nrm = jnp.sqrt(nrm2)
    return jnp.where(tgt_valid, nrm, 1.0)
