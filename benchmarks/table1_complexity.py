"""Paper Table 1: per-phase complexity of the rank-1 SVD update.

Times the three phases separately across n and fits the growth exponent:
  phase A  O(n^2): reduction products (A b, A^T a, projections)
  phase B  O(n^2): secular solve (all updated eigenvalues)
  phase C  O(n^2 log 1/eps) total / O(n p) per Trummer instance:
           singular-vector rotation U @ C via batched FMM
CSV: table1/<phase>/n=<n>,us,<fit info on the largest size>
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.eigh_update import apply_update, make_plan
from repro.core.secular import deflate, secular_solve

SIZES = [128, 256, 512, 1024, 2048]


def run() -> None:
    rng = np.random.default_rng(0)
    results = {"secular": [], "apply_fmm": [], "apply_direct": []}
    for n in SIZES:
        d = np.sort(rng.uniform(1, 9, n))
        z = rng.normal(size=n)
        rho = jnp.asarray(1.1)
        dj, zj = jnp.asarray(d), jnp.asarray(z)
        u = jnp.asarray(np.linalg.qr(rng.normal(size=(n, n)))[0])

        @jax.jit
        def secular_phase(dd, zz):
            defl = deflate(dd, zz, rho)
            dc = dd[defl.compact]
            zc = defl.z[defl.compact]
            return secular_solve(dc, zc, rho, defl.n_keep).mu

        us = time_fn(secular_phase, dj, zj)
        results["secular"].append(us)
        emit(f"table1/secular/n={n}", us, "O(n^2) phase")

        plan_f = make_plan(dj, zj, rho, rho_positive=True, build_fmm=True)
        plan_d = make_plan(dj, zj, rho, rho_positive=True, build_fmm=False)
        us_f = time_fn(jax.jit(lambda w: apply_update(plan_f, w, method="fmm")), u)
        us_d = time_fn(jax.jit(lambda w: apply_update(plan_d, w, method="direct")), u)
        results["apply_fmm"].append(us_f)
        results["apply_direct"].append(us_d)
        emit(f"table1/apply_fmm/n={n}", us_f, "O(n^2 p) total")
        emit(f"table1/apply_direct/n={n}", us_d, "O(n^3) total")

    # growth exponents over the last three points
    ln = np.log(np.asarray(SIZES[-3:], float))
    for phase, us_list in results.items():
        ly = np.log(np.asarray(us_list[-3:]))
        slope = np.polyfit(ln, ly, 1)[0]
        emit(f"table1/exponent/{phase}", us_list[-1], f"n^{slope:.2f}")


if __name__ == "__main__":
    run()
