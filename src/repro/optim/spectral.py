"""Spectral gradient projection — the paper's technique as an optimizer feature.

GaLore-style low-rank optimizer-state compression with one crucial change:
instead of re-running a full SVD every T steps (O(m n r)), each 2-D
parameter keeps a *streaming* truncated SVD of its gradient history that is
updated every step with the paper's rank-1 machinery
(``core.svd_update_truncated``: Brand augmentation + secular/Loewner/Cauchy).

Per step and per (m, n) parameter:
  1. one power-iteration step (warm-started) extracts the dominant rank-1
     component of the fresh gradient: g ≈ sigma * u v^T           O(m n)
  2. the tracker SVD is updated with that rank-1 term               O((m+n) r + r^2 p)
  3. the gradient is projected onto the rank-r left basis: G_p = U_r^T G
     and Adam moments live in the (r, n) projected space            O(m n r) -> O(m r n)

Memory: moments shrink from 2 m n to 2 r n floats (plus the (m+r+1) r
tracker) — the big win for billion-parameter training.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.svd_update import TruncatedSvd, svd_update_truncated

__all__ = ["SpectralState", "spectral_init", "spectral_update_basis", "project", "unproject"]


class SpectralState(NamedTuple):
    tracker: TruncatedSvd     # streaming SVD of the gradient history
    power_v: jax.Array        # (n,) warm-started power-iteration vector
    step: jax.Array


def spectral_init(key, m: int, n: int, rank: int, dtype=jnp.float32) -> SpectralState:
    ku, kv, kp = jax.random.split(key, 3)
    u0, _ = jnp.linalg.qr(jax.random.normal(ku, (m, rank), dtype))
    v0, _ = jnp.linalg.qr(jax.random.normal(kv, (n, rank), dtype))
    return SpectralState(
        tracker=TruncatedSvd(u=u0, s=jnp.zeros((rank,), dtype), v=v0),
        power_v=jax.random.normal(kp, (n,), dtype) / (n ** 0.5),
        step=jnp.zeros((), jnp.int32),
    )


@partial(jax.jit, static_argnames=("method",))
def spectral_update_basis(state: SpectralState, grad: jax.Array, *, decay: float = 0.99,
                          method: str = "direct") -> SpectralState:
    """Fold the fresh gradient's dominant rank-1 component into the tracker."""
    g = grad.astype(state.tracker.u.dtype)

    # one warm-started power iteration: v <- G^T G v / |.|, u = G v / |G v|
    v = state.power_v
    gv = g @ v
    u = gv / (jnp.linalg.norm(gv) + 1e-30)
    gtu = g.T @ u
    sigma = jnp.linalg.norm(gtu)
    v_new = gtu / (sigma + 1e-30)

    # decay the tracker (recency weighting), then rank-1 update via the paper
    tr = state.tracker
    tr = TruncatedSvd(u=tr.u, s=tr.s * decay, v=tr.v)
    tr = svd_update_truncated(tr, u * jnp.sqrt(sigma), v_new * jnp.sqrt(sigma), method=method)
    return SpectralState(tracker=tr, power_v=v_new, step=state.step + 1)


def project(state: SpectralState, grad: jax.Array) -> jax.Array:
    """G_p = U_r^T G  — (r, n) projected gradient."""
    return state.tracker.u.T @ grad.astype(state.tracker.u.dtype)


def unproject(state: SpectralState, update_p: jax.Array) -> jax.Array:
    """Back to parameter space: U_r @ update_p."""
    return state.tracker.u @ update_p
