"""Mixture-of-Experts layer (DeepSeek-MoE style: shared + fine-grained routed).

GShard/Switch-style capacity dispatch: top-k routing with a static per-expert
capacity, dispatch/combine as dense einsums (TPU-native; experts shard over
the ``model`` mesh axis = expert parallelism). Overflowed tokens fall through
on the residual path (standard capacity semantics).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dot, mlp_init, uniform_init


def _constrain(x, spec, cfg):
    """Optional explicit EP sharding annotation (cfg.moe_shard_constraints).
    No-op outside a mesh context."""
    if not cfg.moe_shard_constraints:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg, dtype):
    d = cfg.d_model
    m = cfg.moe
    ks = jax.random.split(key, 5)
    s_in = (1.0 / d) ** 0.5
    s_out = (1.0 / m.d_ff_expert) ** 0.5
    p = {
        "router": uniform_init(ks[0], (d, m.n_routed), s_in, jnp.float32),
        "wg": uniform_init(ks[1], (m.n_routed, d, m.d_ff_expert), s_in, dtype),
        "wu": uniform_init(ks[2], (m.n_routed, d, m.d_ff_expert), s_in, dtype),
        "wd": uniform_init(ks[3], (m.n_routed, m.d_ff_expert, d), s_out, dtype),
    }
    if m.n_shared > 0:
        p["shared"] = mlp_init(ks[4], d, m.n_shared * m.d_ff_expert, "swiglu", dtype)
    return p


def moe_apply(x, p, cfg):
    """x: (b, s, d) -> (b, s, d). Router in f32; experts in compute dtype.

    GShard capacity dispatch over fixed-size groups: the (gs, E, C) one-hot
    tensors are quadratic in group size, so ``group_size`` is held constant
    (default 1024) no matter the global token count — the group axis shards
    over ``data`` and experts over ``model`` (EP).
    """
    b, s, d = x.shape
    m = cfg.moe
    cd = jnp.dtype(cfg.compute_dtype)
    t = b * s
    gs = min(m.group_size, t)
    if t % gs:
        raise ValueError(f"token count {t} not divisible by MoE group size {gs}")
    n_groups = t // gs
    xg = x.reshape(n_groups, gs, d)

    # --- routing (f32 for numerics)
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)             # (g, s, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    capacity = max(1, int(m.capacity_factor * gs * m.top_k / m.n_routed))

    # --- position within expert, per group, over flattened (gs*k) choices
    onehot = jax.nn.one_hot(gate_idx, m.n_routed, dtype=jnp.int32)  # (g, s, k, E)
    flat = onehot.reshape(n_groups, gs * m.top_k, m.n_routed)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat                 # (g, s*k, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(n_groups, gs, m.top_k)
    keep = pos < capacity
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # --- dispatch one-hots as dense einsums (TPU-native EP)
    cap_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity, dtype=cd)
    disp = jnp.einsum("gske,gskc->gsec", onehot.astype(cd), cap_oh)  # (g, s, E, C)
    disp = _constrain(disp, ("data", None, "model", None), cfg)
    x_exp = jnp.einsum("gsec,gsd->gecd", disp, xg.astype(cd))        # (g, E, C, d)
    x_exp = _constrain(x_exp, ("data", "model", None, None), cfg)

    # --- expert FFNs (batched over E; E shards over the model axis = EP)
    g_act = jnp.einsum("gecd,edf->gecf", x_exp, p["wg"].astype(cd),
                       preferred_element_type=jnp.float32)
    u_act = jnp.einsum("gecd,edf->gecf", x_exp, p["wu"].astype(cd),
                       preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g_act) * u_act).astype(cd)
    y_exp = jnp.einsum("gecf,efd->gecd", h, p["wd"].astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
    y_exp = _constrain(y_exp, ("data", "model", None, None), cfg)

    # --- combine (dispatch weighted by gates)
    gate_disp = jnp.einsum("gske,gskc,gsk->gsec", onehot.astype(cd), cap_oh,
                           gate_vals.astype(cd))
    y = jnp.einsum("gsec,gecd->gsd", gate_disp, y_exp)
    out = y.reshape(b, s, d).astype(x.dtype)

    if m.n_shared > 0:
        sh = p["shared"]
        g2 = dot(x, sh["wg"], cd)
        u2 = dot(x, sh["wu"], cd)
        out = out + dot((jax.nn.silu(g2) * u2).astype(x.dtype), sh["wd"], cd).astype(x.dtype)
    return out


def moe_aux_loss(x, p, cfg):
    """Load-balance auxiliary loss (mean fraction * mean prob per expert)."""
    b, s, d = x.shape
    m = cfg.moe
    xf = x.reshape(b * s, d)
    logits = jnp.matmul(xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, m.top_k)
    frac = jnp.mean(jax.nn.one_hot(idx, m.n_routed), axis=(0, 1))
    imp = jnp.mean(probs, axis=0)
    return m.n_routed * jnp.sum(frac * imp)
