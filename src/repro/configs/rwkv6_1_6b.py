"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — "Finch": data-dependent decay linear attention.
[arXiv:2404.05892; unverified]

Runs long_500k (O(1) recurrent state)."""
from repro.configs.base import ModelConfig, RWKVConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,  # heads = d/64
        d_ff=7168, vocab_size=65536,
        mlp_type="swiglu", norm_type="layernorm",
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, chunk=64),
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="rwkv6-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, vocab_pad_to=64,
        rwkv=RWKVConfig(head_dim=16, decay_lora=8, chunk=8),
        compute_dtype="float32", remat=False,
    )
